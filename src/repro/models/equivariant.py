"""Real-basis SO(3) representation machinery for NequIP (l ≤ 3).

Rather than porting e3nn's tables (and risking basis-convention drift), we
derive everything *numerically from our own real spherical harmonics*:

  * ``wigner_d(l, R)`` — fit D_l from Y_l(R x) = D_l Y_l(x) over sample
    points (exact up to lstsq noise, ~1e-12).
  * ``real_cg(l1, l2, l3)`` — the (unique up to scale) equivariant
    bilinear map V_{l1} ⊗ V_{l2} → V_{l3}, found as the nullspace of the
    intertwining constraint stacked over random rotations.

Learned per-path weights absorb the arbitrary normalisation, and the
equivariance *tests* (rotate inputs ⇒ outputs rotate with D_l) hold
against these same conventions by construction.  All of this is plain
numpy at trace time — tables are baked into the jaxpr as constants.
"""
from __future__ import annotations

import functools

import numpy as np


def real_sh(l: int, xyz: np.ndarray) -> np.ndarray:
    """Real spherical harmonics (unnormalised, consistent basis).

    xyz (..., 3) unit vectors -> (..., 2l+1).
    """
    x, y, z = xyz[..., 0], xyz[..., 1], xyz[..., 2]
    if l == 0:
        return np.ones(xyz.shape[:-1] + (1,))
    if l == 1:
        return np.stack([x, y, z], axis=-1)
    if l == 2:
        return np.stack([
            x * y, y * z,
            (3 * z * z - 1.0) / (2 * np.sqrt(3.0)),
            x * z,
            (x * x - y * y) / 2.0,
        ], axis=-1) * np.sqrt(3.0)
    if l == 3:
        return np.stack([
            y * (3 * x * x - y * y),
            x * y * z,
            y * (5 * z * z - 1.0),
            z * (5 * z * z - 3.0),
            x * (5 * z * z - 1.0),
            z * (x * x - y * y),
            x * (x * x - 3 * y * y),
        ], axis=-1)
    raise NotImplementedError(f"l={l}")


def _unit_points(n: int, seed: int = 0) -> np.ndarray:
    rng = np.random.default_rng(seed)
    p = rng.normal(size=(n, 3))
    return p / np.linalg.norm(p, axis=-1, keepdims=True)


def random_rotation(seed: int = 0) -> np.ndarray:
    rng = np.random.default_rng(seed)
    a = rng.normal(size=(3, 3))
    q, r = np.linalg.qr(a)
    q = q * np.sign(np.diag(r))
    if np.linalg.det(q) < 0:
        q[:, 0] = -q[:, 0]
    return q


@functools.lru_cache(maxsize=None)
def _sh_basis_cache(l: int):
    pts = _unit_points(max(64, 8 * (2 * l + 1)), seed=l + 1)
    return pts, np.linalg.pinv(real_sh(l, pts))


def wigner_d(l: int, rotation: np.ndarray) -> np.ndarray:
    """D_l with Y_l(R x) = D_l(R) Y_l(x) in our real basis. (2l+1, 2l+1)."""
    pts, pinvA = _sh_basis_cache(l)
    b = real_sh(l, pts @ rotation.T)          # Y(R x_p)
    return (pinvA @ b).T


@functools.lru_cache(maxsize=None)
def real_cg(l1: int, l2: int, l3: int) -> np.ndarray:
    """Real Clebsch-Gordan tensor T (2l3+1, 2l1+1, 2l2+1), or zeros when
    the triangle inequality fails.  Normalised to unit Frobenius norm."""
    n1, n2, n3 = 2 * l1 + 1, 2 * l2 + 1, 2 * l3 + 1
    if not (abs(l1 - l2) <= l3 <= l1 + l2):
        return np.zeros((n3, n1, n2))
    rows = []
    for s in range(4):
        rot = random_rotation(seed=100 + 7 * s + l1 + 10 * l2 + 100 * l3)
        d1, d2, d3 = wigner_d(l1, rot), wigner_d(l2, rot), wigner_d(l3, rot)
        # constraint: Σ T[m3,m1,m2] D1[m1,a] D2[m2,b] = Σ D3[m3,c] T[c,a,b]
        lhs = np.einsum("ma,nb->manb", d1, d2)       # (n1,n1',n2,n2')
        block = np.zeros((n3 * n1 * n2, n3 * n1 * n2))
        # unknowns vec(T) with index (m3, m1, m2)
        for m3 in range(n3):
            for a in range(n1):
                for b in range(n2):
                    row = np.zeros((n3, n1, n2))
                    row[m3] += lhs[:, a, :, b]
                    for c in range(n3):
                        row[c, a, b] -= d3[m3, c]
                    block[(m3 * n1 + a) * n2 + b] = row.reshape(-1)
        rows.append(block)
    mat = np.concatenate(rows, axis=0)
    _, sing, vt = np.linalg.svd(mat)
    # scale-aware tolerance; the (0,0,0) constraint matrix is identically 0
    null_dim = int(np.sum(sing < 1e-8 * max(sing[0], 1e-3)))
    if null_dim != 1:
        raise RuntimeError(
            f"CG nullspace for ({l1},{l2},{l3}) has dim {null_dim}")
    t = vt[-1].reshape(n3, n1, n2)
    return t / np.linalg.norm(t)


def allowed_paths(l_max: int):
    """All (l_in, l_filter, l_out) triples with every l <= l_max."""
    out = []
    for l1 in range(l_max + 1):
        for l2 in range(l_max + 1):
            for l3 in range(l_max + 1):
                if abs(l1 - l2) <= l3 <= l1 + l2:
                    out.append((l1, l2, l3))
    return out
