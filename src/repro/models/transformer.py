"""Decoder-only transformer family covering the assigned LM architectures.

One implementation parameterised to produce:
  * dense SwiGLU + GQA  (phi3-mini, granite-3-8b, granite-3-2b)
  * MoE (GShard capacity dispatch) + GQA (dbrx-132b)
  * MoE + MLA compressed-KV attention (deepseek-v2-lite)

Layers are *stacked* (leading L axis) and executed with ``lax.scan`` so
the traced HLO contains one layer body regardless of depth — required for
the 512-device dry-run to compile on this container, and the right
production choice (constant compile time, remat-friendly).

Decode uses an explicit KV cache:
  * GQA: (L, B, T, Hk, Dh) K/V
  * MLA: (L, B, T, r) latent + (L, B, T, dr) shared rope key — the paper's
    compressed cache — with the **absorbed-matrix** decode path
    (q·W_uk folded into the query) so decode never expands K/V.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.constraints import constrain
from repro.models import layers
from repro.models.moe import MoEConfig, moe_ffn

Params = Dict[str, Any]


@dataclasses.dataclass(frozen=True)
class LMConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None
    # MoE
    moe: bool = False
    n_experts: int = 0
    top_k: int = 0
    n_shared: int = 0
    moe_d_ff: int = 0
    capacity_factor: float = 1.25
    # dispatch-mask memory/FLOPs are quadratic in group size (mask is
    # N_g × E×C with C ∝ N_g) — keep groups small (see repro.models.moe)
    moe_group_size: int = 512
    # MLA
    mla: bool = False
    kv_lora_rank: int = 0
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128
    # attention / misc
    rope_theta: float = 1e4
    q_chunk: int = 512
    kv_chunk: int = 1024
    dtype: str = "bfloat16"
    remat: bool = True

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def jdtype(self):
        return jnp.dtype(self.dtype)

    @property
    def moe_cfg(self) -> MoEConfig:
        return MoEConfig(n_experts=self.n_experts, top_k=self.top_k,
                         d_model=self.d_model, d_ff=self.moe_d_ff,
                         n_shared=self.n_shared,
                         capacity_factor=self.capacity_factor,
                         group_size=self.moe_group_size)

    def param_count(self) -> int:
        leaves = jax.tree.leaves(jax.eval_shape(
            lambda: init_params(self, jax.random.PRNGKey(0))))
        return sum(int(jnp.prod(jnp.asarray(l.shape))) for l in leaves)


# --------------------------------------------------------------------------
# parameter init (stacked layers)
# --------------------------------------------------------------------------

def _dense_init(key, shape, scale, dtype):
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def init_layer_params(cfg: LMConfig, key: jax.Array) -> Params:
    """One layer's params, *without* the leading L axis."""
    d, hd = cfg.d_model, cfg.hd
    dt = cfg.jdtype
    s = 0.02
    so = 0.02 / max(1.0, (2 * cfg.n_layers) ** 0.5)
    ks = jax.random.split(key, 16)
    p: Params = {
        "ln_attn": jnp.ones((d,), dt),
        "ln_mlp": jnp.ones((d,), dt),
    }
    if cfg.mla:
        dn, dr, dv, r = (cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim,
                         cfg.kv_lora_rank)
        p["wq"] = _dense_init(ks[0], (d, cfg.n_heads, dn + dr), s, dt)
        p["w_dkv"] = _dense_init(ks[1], (d, r + dr), s, dt)
        p["w_uk"] = _dense_init(ks[2], (r, cfg.n_heads, dn), s, dt)
        p["w_uv"] = _dense_init(ks[3], (r, cfg.n_heads, dv), s, dt)
        p["wo"] = _dense_init(ks[4], (cfg.n_heads, dv, d), so, dt)
    else:
        p["wq"] = _dense_init(ks[0], (d, cfg.n_heads, hd), s, dt)
        p["wk"] = _dense_init(ks[1], (d, cfg.n_kv_heads, hd), s, dt)
        p["wv"] = _dense_init(ks[2], (d, cfg.n_kv_heads, hd), s, dt)
        p["wo"] = _dense_init(ks[4], (cfg.n_heads, hd, d), so, dt)
    if cfg.moe:
        e, ff = cfg.n_experts, cfg.moe_d_ff
        p["router"] = _dense_init(ks[5], (d, e), s, jnp.float32)
        p["we_gate"] = _dense_init(ks[6], (e, d, ff), s, dt)
        p["we_up"] = _dense_init(ks[7], (e, d, ff), s, dt)
        p["we_down"] = _dense_init(ks[8], (e, ff, d), so, dt)
        if cfg.n_shared:
            sf = cfg.n_shared * ff
            p["ws_gate"] = _dense_init(ks[9], (d, sf), s, dt)
            p["ws_up"] = _dense_init(ks[10], (d, sf), s, dt)
            p["ws_down"] = _dense_init(ks[11], (sf, d), so, dt)
    else:
        p["w_gate"] = _dense_init(ks[6], (d, cfg.d_ff), s, dt)
        p["w_up"] = _dense_init(ks[7], (d, cfg.d_ff), s, dt)
        p["w_down"] = _dense_init(ks[8], (cfg.d_ff, d), so, dt)
    return p


def init_params(cfg: LMConfig, key: jax.Array) -> Params:
    k_emb, k_head, k_layers = jax.random.split(key, 3)
    layer_keys = jax.random.split(k_layers, cfg.n_layers)
    stacked = jax.vmap(lambda k: init_layer_params(cfg, k))(layer_keys)
    return {
        "embed": _dense_init(k_emb, (cfg.vocab, cfg.d_model), 0.02,
                             cfg.jdtype),
        "head": _dense_init(k_head, (cfg.d_model, cfg.vocab), 0.02,
                            cfg.jdtype),
        "ln_f": jnp.ones((cfg.d_model,), cfg.jdtype),
        "layers": stacked,
    }


# --------------------------------------------------------------------------
# attention variants
# --------------------------------------------------------------------------

def _gqa_attention(p: Params, x: jnp.ndarray, cfg: LMConfig,
                   positions: jnp.ndarray) -> jnp.ndarray:
    q = constrain(jnp.einsum("bsd,dhk->bshk", x, p["wq"]),
                  "batch", None, "heads", None)
    # K/V replicate across the model axis when kv_heads < TP: the chunked
    # attention repeats them to full heads locally, so q's head sharding
    # flows end-to-end (sharding K/V on head_dim forced per-chunk
    # all-gathers — see EXPERIMENTS.md §Perf granite prefill iteration)
    k = constrain(jnp.einsum("bsd,dhk->bshk", x, p["wk"]),
                  "batch", None, "heads", None)
    v = constrain(jnp.einsum("bsd,dhk->bshk", x, p["wv"]),
                  "batch", None, "heads", None)
    q = layers.apply_rope(q, positions, cfg.rope_theta)
    k = layers.apply_rope(k, positions, cfg.rope_theta)
    o = layers.chunked_attention(q, k, v, causal=True,
                                 q_chunk=cfg.q_chunk, kv_chunk=cfg.kv_chunk)
    o = constrain(o, "batch", None, "heads", None)
    return jnp.einsum("bshk,hkd->bsd", o, p["wo"])


def _mla_attention(p: Params, x: jnp.ndarray, cfg: LMConfig,
                   positions: jnp.ndarray) -> jnp.ndarray:
    dn, dr = cfg.qk_nope_dim, cfg.qk_rope_dim
    r = cfg.kv_lora_rank
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = layers.apply_rope(q_rope, positions, cfg.rope_theta)

    ckv = jnp.einsum("bsd,dk->bsk", x, p["w_dkv"])
    c, k_rope = ckv[..., :r], ckv[..., r:]
    k_rope = layers.apply_rope(k_rope[:, :, None, :], positions,
                               cfg.rope_theta)             # (B,S,1,dr)
    k_nope = jnp.einsum("bsr,rhk->bshk", c, p["w_uk"])
    v = jnp.einsum("bsr,rhk->bshk", c, p["w_uv"])

    q_full = jnp.concatenate([q_nope, q_rope], axis=-1)
    k_full = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope, k_nope.shape[:-1] + (dr,))],
        axis=-1)
    o = layers.chunked_attention(
        q_full, k_full, v, causal=True, q_chunk=cfg.q_chunk,
        kv_chunk=cfg.kv_chunk, scale=(dn + dr) ** -0.5)
    return jnp.einsum("bshk,hkd->bsd", o, p["wo"])


# --------------------------------------------------------------------------
# layer body / full forward
# --------------------------------------------------------------------------

def _ffn(p: Params, x: jnp.ndarray, cfg: LMConfig
         ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    if not cfg.moe:
        return (layers.swiglu(x, p["w_gate"], p["w_up"], p["w_down"]),
                jnp.zeros((), jnp.float32))
    y, aux = moe_ffn(x, p["router"], p["we_gate"], p["we_up"],
                     p["we_down"], cfg.moe_cfg)
    if cfg.n_shared:
        y = y + layers.swiglu(x, p["ws_gate"], p["ws_up"], p["ws_down"])
    return y, aux


def _layer(p: Params, x: jnp.ndarray, cfg: LMConfig,
           positions: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    attn = _mla_attention if cfg.mla else _gqa_attention
    x = constrain(x, "batch", None, None)
    h = x + attn(p, layers.rms_norm(x, p["ln_attn"]), cfg, positions)
    h = constrain(h, "batch", None, None)
    y, aux = _ffn(p, layers.rms_norm(h, p["ln_mlp"]), cfg)
    out = constrain(h + y, "batch", None, None)
    return out, aux


def forward(params: Params, tokens: jnp.ndarray, cfg: LMConfig
            ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """tokens (B, S) -> (logits (B, S, V), aux_loss)."""
    x = params["embed"][tokens].astype(cfg.jdtype)
    x = constrain(x, "batch", None, None)
    positions = jnp.arange(tokens.shape[1])

    def body(carry, layer_p):
        x = carry
        fn = _layer
        if cfg.remat:
            fn = jax.checkpoint(
                _layer, policy=jax.checkpoint_policies.nothing_saveable,
                static_argnums=(2,))
        x, aux = fn(layer_p, x, cfg, positions)
        return x, aux

    x, auxs = jax.lax.scan(lambda c, p: body(c, p), x, params["layers"])
    x = layers.rms_norm(x, params["ln_f"])
    logits = jnp.einsum("bsd,dv->bsv", x, params["head"])
    logits = constrain(logits, "batch", None, "vocab")
    return logits, jnp.sum(auxs)


def loss_fn(params: Params, batch: Dict[str, jnp.ndarray], cfg: LMConfig
            ) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    logits, aux = forward(params, batch["tokens"], cfg)
    ce = layers.cross_entropy_loss(logits, batch["labels"])
    loss = ce + 0.01 * aux
    return loss, {"ce": ce, "aux": aux}


# --------------------------------------------------------------------------
# serving: prefill + decode with KV cache
# --------------------------------------------------------------------------

def init_cache(cfg: LMConfig, batch: int, max_len: int) -> Params:
    dt = cfg.jdtype
    if cfg.mla:
        return {
            "c": jnp.zeros((cfg.n_layers, batch, max_len,
                            cfg.kv_lora_rank), dt),
            "k_rope": jnp.zeros((cfg.n_layers, batch, max_len,
                                 cfg.qk_rope_dim), dt),
            "length": jnp.zeros((batch,), jnp.int32),
        }
    return {
        "k": jnp.zeros((cfg.n_layers, batch, max_len, cfg.n_kv_heads,
                        cfg.hd), dt),
        "v": jnp.zeros((cfg.n_layers, batch, max_len, cfg.n_kv_heads,
                        cfg.hd), dt),
        "length": jnp.zeros((batch,), jnp.int32),
    }


def _cache_insert(cache_l: jnp.ndarray, new: jnp.ndarray,
                  lengths: jnp.ndarray) -> jnp.ndarray:
    """Insert one new timestep at per-row position ``lengths``.

    cache_l (B, T, ...), new (B, 1, ...), lengths (B,).
    """
    def one(row_cache, row_new, pos):
        return jax.lax.dynamic_update_slice_in_dim(row_cache, row_new,
                                                   pos, axis=0)
    return jax.vmap(one)(cache_l, new, lengths)


def _gqa_decode_layer(p: Params, x: jnp.ndarray, k_c, v_c, lengths, cfg):
    positions = lengths[:, None]                         # (B, 1)
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    q = layers.apply_rope(q, positions, cfg.rope_theta)
    k = layers.apply_rope(k, positions, cfg.rope_theta)
    k_c = _cache_insert(k_c, k, lengths)
    v_c = _cache_insert(v_c, v, lengths)
    o = layers.decode_attention(q, k_c, v_c, kv_valid=lengths + 1)
    return jnp.einsum("bshk,hkd->bsd", o, p["wo"]), k_c, v_c


def _mla_decode_layer(p: Params, x: jnp.ndarray, c_c, kr_c, lengths, cfg):
    """Absorbed-matrix MLA decode: attention runs in the latent space."""
    dn, dr, r = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.kv_lora_rank
    positions = lengths[:, None]
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = layers.apply_rope(q_rope, positions, cfg.rope_theta)

    ckv = jnp.einsum("bsd,dk->bsk", x, p["w_dkv"])
    c_new, kr_new = ckv[..., :r], ckv[..., r:]
    kr_new = layers.apply_rope(kr_new[:, :, None, :], positions,
                               cfg.rope_theta)[:, :, 0, :]
    c_c = _cache_insert(c_c, c_new, lengths)
    kr_c = _cache_insert(kr_c, kr_new, lengths)

    # fold W_uk into the query: q_lat = q_nope @ W_uk  (B,1,H,r)
    q_lat = jnp.einsum("bshk,rhk->bshr", q_nope, p["w_uk"])
    scale = (dn + dr) ** -0.5
    logits = (jnp.einsum("bshr,btr->bhst", q_lat.astype(jnp.float32),
                         c_c.astype(jnp.float32))
              + jnp.einsum("bshk,btk->bhst", q_rope.astype(jnp.float32),
                           kr_c.astype(jnp.float32))) * scale
    t = c_c.shape[1]
    mask = jnp.arange(t)[None, :] < (lengths + 1)[:, None]
    logits = jnp.where(mask[:, None, None, :], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    o_lat = jnp.einsum("bhst,btr->bshr", probs.astype(c_c.dtype), c_c)
    o = jnp.einsum("bshr,rhk->bshk", o_lat, p["w_uv"])
    return jnp.einsum("bshk,hkd->bsd", o, p["wo"]), c_c, kr_c


def decode_step(params: Params, cache: Params, tokens: jnp.ndarray,
                cfg: LMConfig) -> Tuple[jnp.ndarray, Params]:
    """One decode step: tokens (B, 1) -> (logits (B, 1, V), new cache)."""
    x = params["embed"][tokens].astype(cfg.jdtype)
    lengths = cache["length"]

    if cfg.mla:
        def body(carry, inputs):
            x = carry
            layer_p, c_l, kr_l = inputs
            h = x
            a, c_l, kr_l = _mla_decode_layer(
                layer_p, layers.rms_norm(x, layer_p["ln_attn"]), c_l, kr_l,
                lengths, cfg)
            h = h + a
            y, _ = _ffn(layer_p, layers.rms_norm(h, layer_p["ln_mlp"]), cfg)
            return h + y, (c_l, kr_l)

        x, (c_new, kr_new) = jax.lax.scan(
            body, x, (params["layers"], cache["c"], cache["k_rope"]))
        new_cache = {"c": c_new, "k_rope": kr_new, "length": lengths + 1}
    else:
        def body(carry, inputs):
            x = carry
            layer_p, k_l, v_l = inputs
            h = x
            a, k_l, v_l = _gqa_decode_layer(
                layer_p, layers.rms_norm(x, layer_p["ln_attn"]), k_l, v_l,
                lengths, cfg)
            h = h + a
            y, _ = _ffn(layer_p, layers.rms_norm(h, layer_p["ln_mlp"]), cfg)
            return h + y, (k_l, v_l)

        x, (k_new, v_new) = jax.lax.scan(
            body, x, (params["layers"], cache["k"], cache["v"]))
        new_cache = {"k": k_new, "v": v_new, "length": lengths + 1}

    x = layers.rms_norm(x, params["ln_f"])
    logits = jnp.einsum("bsd,dv->bsv", x, params["head"])
    return logits, new_cache


def prefill(params: Params, tokens: jnp.ndarray, cfg: LMConfig
            ) -> jnp.ndarray:
    """Prefill serve step: full forward, returns last-position logits."""
    logits, _ = forward(params, tokens, cfg)
    return logits[:, -1:, :]
