"""Shared neural-net layers (pure jnp; everything shards under GSPMD).

The attention here is the *memory-efficient chunked (flash-style)
online-softmax* implementation — `lax.map` over query chunks with an inner
`lax.scan` over KV chunks — so a 32k-token prefill never materialises the
(S, T) logits matrix (peak is (q_chunk, kv_chunk) per head).  This is the
form the multi-pod dry-run lowers; on real TPU the same API can dispatch
to a Pallas flash kernel.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp


def rms_norm(x: jnp.ndarray, weight: jnp.ndarray, eps: float = 1e-6
             ) -> jnp.ndarray:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps)).astype(dtype) * weight


def rope_frequencies(head_dim: int, theta: float = 1e4) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray,
               theta: float = 1e4) -> jnp.ndarray:
    """Rotary embedding. x (B, S, H, D), positions (B, S) or (S,)."""
    d = x.shape[-1]
    freqs = rope_frequencies(d, theta)                     # (D/2,)
    if positions.ndim == 1:
        positions = positions[None, :]
    angles = positions[..., None].astype(jnp.float32) * freqs  # (B, S, D/2)
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos],
                          axis=-1)
    return out.astype(x.dtype)


def swiglu(x: jnp.ndarray, w_gate: jnp.ndarray, w_up: jnp.ndarray,
           w_down: jnp.ndarray) -> jnp.ndarray:
    """SwiGLU MLP: (..., d) @ (d, ff) pair -> (..., d)."""
    h = jax.nn.silu(x @ w_gate) * (x @ w_up)
    return h @ w_down


def _attend_block(q_blk, k_blk, v_blk, scale, mask):
    """One (q_chunk, kv_chunk) attention tile with explicit f32 softmax stats.

    q_blk (B, qc, H, D), k_blk (B, kc, H, D), v_blk (B, kc, H, Dv),
    mask (B, qc, kc) or broadcastable. Returns logits-stats tuple.

    GQA note: K/V arrive pre-expanded to the full H query heads (a local
    repeat of the kv heads).  Keeping ONE head axis lets the `model`
    sharding of q heads flow through the whole tile — factoring heads as
    (Hk, g) forced GSPMD to all-gather every K/V chunk when Hk < TP
    (3.4e11 B/step on granite prefill_32k; EXPERIMENTS.md §Perf).
    """
    logits = jnp.einsum("bqhd,bkhd->bhqk", q_blk, k_blk,
                        preferred_element_type=jnp.float32) * scale
    logits = jnp.where(mask[:, None, :, :], logits, -1e30)
    m = jnp.max(logits, axis=-1)                       # (B,H,qc)
    p = jnp.exp(logits - m[..., None])
    l = jnp.sum(p, axis=-1)
    acc = jnp.einsum("bhqk,bkhd->bhqd", p.astype(v_blk.dtype), v_blk,
                     preferred_element_type=jnp.float32)
    return m, l, acc


@functools.partial(jax.jit, static_argnames=("causal", "q_chunk",
                                             "kv_chunk", "scale"))
def chunked_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                      causal: bool = True,
                      q_chunk: int = 512, kv_chunk: int = 1024,
                      scale: Optional[float] = None,
                      kv_valid: Optional[jnp.ndarray] = None
                      ) -> jnp.ndarray:
    """Flash-style attention with GQA.

    q (B, S, H, D); k (B, T, Hk, D); v (B, T, Hk, Dv); H % Hk == 0.
    ``kv_valid``: optional (B,) number of valid KV positions (decode).
    Returns (B, S, H, Dv).
    """
    b, s, h, d = q.shape
    t, hk, dv = k.shape[1], k.shape[2], v.shape[-1]
    g = h // hk
    scale = scale if scale is not None else d ** -0.5

    qc = min(q_chunk, s)
    kc = min(kv_chunk, t)
    sp, tp = (-s) % qc, (-t) % kc
    qp = jnp.pad(q, ((0, 0), (0, sp), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, tp), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, tp), (0, 0), (0, 0)))
    nq, nk = (s + sp) // qc, (t + tp) // kc

    q_r = qp.reshape(b, nq, qc, h, d).transpose(1, 0, 2, 3, 4)
    k_r = kp.reshape(b, nk, kc, hk, d)
    v_r = vp.reshape(b, nk, kc, hk, dv)
    iq = jnp.arange(qc)
    ik = jnp.arange(kc)

    def per_q_chunk(args):
        q_blk, q_idx = args
        q_pos = q_idx * qc + iq                              # (qc,)

        def kv_step(carry, k_idx):
            m, l, acc = carry
            k_blk = jax.lax.dynamic_index_in_dim(k_r, k_idx, 1,
                                                 keepdims=False)
            v_blk = jax.lax.dynamic_index_in_dim(v_r, k_idx, 1,
                                                 keepdims=False)
            if g > 1:   # expand kv heads locally (GQA)
                k_blk = jnp.repeat(k_blk, g, axis=2)
                v_blk = jnp.repeat(v_blk, g, axis=2)
            k_pos = k_idx * kc + ik
            mask = jnp.ones((b, qc, kc), bool)
            if causal:
                # decode (s < t): query i sits at absolute pos T - S + i
                q_abs = q_pos + (t - s)
                mask &= (q_abs[:, None] >= k_pos[None, :])[None]
            mask &= (k_pos < t)[None, None, :]
            if kv_valid is not None:
                mask &= (k_pos[None, :] < kv_valid[:, None])[:, None, :]
            m2, l2, a2 = _attend_block(q_blk, k_blk, v_blk, scale, mask)
            m_new = jnp.maximum(m, m2)
            c1 = jnp.exp(m - m_new)
            c2 = jnp.exp(m2 - m_new)
            l_new = l * c1 + l2 * c2
            acc_new = acc * c1[..., None] + a2 * c2[..., None]
            return (m_new, l_new, acc_new), ()

        init = (jnp.full((b, h, qc), -1e30, jnp.float32),
                jnp.zeros((b, h, qc), jnp.float32),
                jnp.zeros((b, h, qc, dv), jnp.float32))
        (m, l, acc), _ = jax.lax.scan(kv_step, init, jnp.arange(nk))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return out                                          # (b,h,qc,dv)

    outs = jax.lax.map(per_q_chunk, (q_r, jnp.arange(nq)))  # (nq,b,h,qc,dv)
    outs = outs.transpose(1, 0, 3, 2, 4).reshape(b, nq * qc, h, dv)
    return outs[:, :s].astype(v.dtype)


@functools.partial(jax.jit, static_argnames=("scale",))
def decode_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                     kv_valid: jnp.ndarray,
                     scale: Optional[float] = None) -> jnp.ndarray:
    """Single-step decode attention over a (possibly huge) KV cache.

    q (B, 1, H, D); k (B, T, Hk, D); v (B, T, Hk, Dv); kv_valid (B,).
    One query token ⇒ logits are (B, H, T) — linear in T, no chunking
    needed (the cache's T axis may be sharded; GSPMD inserts the partial
    softmax collectives).
    """
    b, _, h, d = q.shape
    t, hk, dv = k.shape[1], k.shape[2], v.shape[-1]
    g = h // hk
    scale = scale if scale is not None else d ** -0.5
    qr = q.reshape(b, hk, g, d)
    logits = jnp.einsum("bhgd,bkhd->bhgk", qr, k,
                        preferred_element_type=jnp.float32) * scale
    mask = jnp.arange(t)[None, :] < kv_valid[:, None]        # (B, T)
    logits = jnp.where(mask[:, None, None, :], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgk,bkhd->bhgd", probs.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.reshape(b, 1, h, dv).astype(v.dtype)


def cross_entropy_loss(logits: jnp.ndarray, labels: jnp.ndarray,
                       mask: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Token-mean cross entropy. logits (..., V) f32-upcast internally."""
    logits = logits.astype(jnp.float32)
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is not None:
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)
