"""repro.loadgen — synthetic traffic + closed-loop load harness
(DESIGN.md §12).

Public API:
  poisson_arrivals / mmpp_arrivals / diurnal_arrivals / make_arrivals
      — seeded arrival-time generators (ARRIVAL_PROCESSES registry)
  Mixture / WorkloadSpec / RequestTrace / generate_trace
      — declarative workload → replayable per-request trace
  run_trace / sweep / LoadResult
      — drive a live ServingEngine, measure coordinated-omission-safe
        latency, derive max_sustainable_qps over an offered-load ladder
"""
from repro.loadgen.arrivals import (ARRIVAL_PROCESSES, diurnal_arrivals,
                                    make_arrivals, mmpp_arrivals,
                                    poisson_arrivals)
from repro.loadgen.harness import LoadResult, run_trace, sweep
from repro.loadgen.workload import (Mixture, RequestTrace, WorkloadSpec,
                                    generate_trace)

__all__ = [
    "ARRIVAL_PROCESSES", "poisson_arrivals", "mmpp_arrivals",
    "diurnal_arrivals", "make_arrivals",
    "Mixture", "WorkloadSpec", "RequestTrace", "generate_trace",
    "LoadResult", "run_trace", "sweep",
]
