"""Synthetic arrival processes for the load harness (DESIGN.md §12).

Every generator maps ``(rate_qps, n, seed)`` to a sorted float64 array
of *absolute* arrival offsets in seconds from the start of the run —
fully determined by the seed, so a trace replays bit-identically across
engines, policies, and processes.  All processes are normalized to the
same mean rate: over a long trace, ``n / arrivals[-1] ≈ rate_qps``, so
an offered-load sweep compares like against like regardless of shape.

  poisson   — memoryless baseline: i.i.d. exponential inter-arrivals.
  mmpp      — bursty 2-state Markov-modulated Poisson process: dwell in
              a quiet state at rate r, jump to a burst state at
              ``burst_factor * r``, exponential dwell times; the state
              rates are chosen so the long-run mean is ``rate_qps``.
  diurnal   — slow sinusoidal ramp (a compressed day): nonhomogeneous
              Poisson via Lewis-Shedler thinning against the peak rate.
"""
from __future__ import annotations

import numpy as np


def _validate(rate_qps: float, n: int) -> None:
    if rate_qps <= 0:
        raise ValueError(f"rate_qps must be > 0, got {rate_qps}")
    if n < 1:
        raise ValueError(f"n must be >= 1, got {n}")


def poisson_arrivals(rate_qps: float, n: int, seed: int = 0) -> np.ndarray:
    """Homogeneous Poisson: exponential inter-arrivals at ``rate_qps``."""
    _validate(rate_qps, n)
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(1.0 / rate_qps, size=n)
    return np.cumsum(gaps)


def mmpp_arrivals(rate_qps: float, n: int, seed: int = 0,
                  burst_factor: float = 4.0,
                  dwell_s: float = 0.25) -> np.ndarray:
    """2-state MMPP with equal expected dwell in quiet and burst states.

    With dwell times symmetric, each state carries probability 1/2, so
    the quiet rate solves ``(r + burst_factor * r) / 2 = rate_qps``.
    """
    _validate(rate_qps, n)
    if burst_factor < 1.0:
        raise ValueError(f"burst_factor must be >= 1, got {burst_factor}")
    rng = np.random.default_rng(seed)
    r_quiet = 2.0 * rate_qps / (1.0 + burst_factor)
    rates = (r_quiet, burst_factor * r_quiet)
    out = np.empty(n)
    t, state = 0.0, 0
    t_switch = rng.exponential(dwell_s)
    for k in range(n):
        gap = rng.exponential(1.0 / rates[state])
        # state switches between arrivals restart the residual gap —
        # exact for exponentials (memorylessness)
        while t + gap > t_switch:
            frac = (t_switch - t) / gap        # survive to the switch
            t = t_switch
            state = 1 - state
            t_switch = t + rng.exponential(dwell_s)
            gap = (1.0 - frac) * gap * rates[1 - state] / rates[state]
        t += gap
        out[k] = t
    return out


def diurnal_arrivals(rate_qps: float, n: int, seed: int = 0,
                     period_s: float = 20.0,
                     depth: float = 0.8) -> np.ndarray:
    """Sinusoidal ramp: rate(t) = rate_qps * (1 + depth·sin(2πt/T)).

    Lewis–Shedler thinning against the peak rate keeps the process an
    exact nonhomogeneous Poisson (mean rate ``rate_qps`` by symmetry).
    """
    _validate(rate_qps, n)
    if not 0.0 <= depth < 1.0:
        raise ValueError(f"depth must be in [0, 1), got {depth}")
    rng = np.random.default_rng(seed)
    peak = rate_qps * (1.0 + depth)
    out = np.empty(n)
    t, k = 0.0, 0
    while k < n:
        t += rng.exponential(1.0 / peak)
        rate_t = rate_qps * (1.0 + depth * np.sin(2 * np.pi * t / period_s))
        if rng.uniform() * peak <= rate_t:
            out[k] = t
            k += 1
    return out


ARRIVAL_PROCESSES = {
    "poisson": poisson_arrivals,
    "mmpp": mmpp_arrivals,
    "diurnal": diurnal_arrivals,
}


def make_arrivals(process: str, rate_qps: float, n: int, seed: int = 0,
                  **kwargs) -> np.ndarray:
    """Dispatch by process name (``ARRIVAL_PROCESSES`` keys)."""
    try:
        fn = ARRIVAL_PROCESSES[process]
    except KeyError:
        raise ValueError(
            f"unknown arrival process {process!r}; "
            f"choose from {sorted(ARRIVAL_PROCESSES)}") from None
    return fn(rate_qps, n, seed, **kwargs)
