"""Workload specification → replayable request trace (DESIGN.md §12).

A ``WorkloadSpec`` is declarative and frozen: arrival process + rate +
mixtures over query length and top-k.  ``generate_trace`` lowers it to a
``RequestTrace`` — plain numpy arrays fully determined by the spec's
seed, so the *same* trace can be replayed against different engines and
batch policies (the bit-identity tests depend on exactly this).

Query *content* is deliberately indirect: the trace carries pool indices
per request, not series — callers pair a trace with a query pool (any
array of shape ``(pool_size, length)`` per length in the mixture), so a
trace generated once drives synthetic ECG today and a real dataset
tomorrow without re-deriving arrival times.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Mapping, Sequence, Tuple

import numpy as np

from repro.loadgen.arrivals import ARRIVAL_PROCESSES, make_arrivals


@dataclasses.dataclass(frozen=True)
class Mixture:
    """Discrete distribution over workload attribute values."""

    values: Tuple[int, ...]
    weights: Tuple[float, ...] = ()

    def validate(self) -> "Mixture":
        if not self.values:
            raise ValueError("Mixture needs at least one value")
        if self.weights and len(self.weights) != len(self.values):
            raise ValueError(
                f"weights ({len(self.weights)}) must match values "
                f"({len(self.values)})")
        if self.weights and (min(self.weights) < 0
                             or sum(self.weights) <= 0):
            raise ValueError("weights must be non-negative with a "
                             "positive sum")
        return self

    def probabilities(self) -> np.ndarray:
        if not self.weights:
            return np.full(len(self.values), 1.0 / len(self.values))
        w = np.asarray(self.weights, dtype=np.float64)
        return w / w.sum()

    def sample(self, rng: np.random.Generator, n: int) -> np.ndarray:
        self.validate()
        return rng.choice(np.asarray(self.values), size=n,
                          p=self.probabilities())

    def to_dict(self) -> Dict[str, Any]:
        return {"values": list(self.values), "weights": list(self.weights)}

    @classmethod
    def from_dict(cls, d: Mapping[str, Any]) -> "Mixture":
        return cls(tuple(d["values"]), tuple(d.get("weights", ())))


@dataclasses.dataclass(frozen=True)
class WorkloadSpec:
    """Everything that determines a synthetic traffic trace."""

    process: str = "poisson"            # ARRIVAL_PROCESSES key
    rate_qps: float = 50.0              # mean offered load
    n_requests: int = 256
    seed: int = 0
    lengths: Mixture = Mixture((128,))  # query length mixture
    topks: Mixture = Mixture((10,))     # per-request top-k mixture
    # process-specific shape knobs (ignored by processes not using them)
    burst_factor: float = 4.0           # mmpp: burst/quiet rate ratio
    dwell_s: float = 0.25               # mmpp: mean state dwell
    period_s: float = 20.0              # diurnal: ramp period
    depth: float = 0.8                  # diurnal: modulation depth

    def validate(self) -> "WorkloadSpec":
        if self.process not in ARRIVAL_PROCESSES:
            raise ValueError(
                f"unknown arrival process {self.process!r}; choose from "
                f"{sorted(ARRIVAL_PROCESSES)}")
        if self.rate_qps <= 0:
            raise ValueError(f"rate_qps must be > 0, got {self.rate_qps}")
        if self.n_requests < 1:
            raise ValueError(
                f"n_requests must be >= 1, got {self.n_requests}")
        self.lengths.validate()
        self.topks.validate()
        if min(self.topks.values) < 1:
            raise ValueError("every topk in the mixture must be >= 1")
        return self

    def replace(self, **changes) -> "WorkloadSpec":
        return dataclasses.replace(self, **changes).validate()

    def to_dict(self) -> Dict[str, Any]:
        d = dataclasses.asdict(self)
        d["lengths"] = self.lengths.to_dict()
        d["topks"] = self.topks.to_dict()
        return d


@dataclasses.dataclass(frozen=True)
class RequestTrace:
    """A lowered workload: one row per request, seeded and replayable.

    ``pool_ids`` index into a caller-supplied query pool *for that
    request's length* — the trace never owns series data.
    """

    spec: WorkloadSpec
    arrivals_s: np.ndarray              # sorted absolute offsets (s)
    lengths: np.ndarray                 # per-request query length
    topks: np.ndarray                   # per-request top-k
    pool_ids: np.ndarray                # per-request index into the pool

    def __len__(self) -> int:
        return int(self.arrivals_s.shape[0])

    @property
    def duration_s(self) -> float:
        return float(self.arrivals_s[-1])


def generate_trace(spec: WorkloadSpec,
                   pool_sizes: Mapping[int, int]) -> RequestTrace:
    """Lower a spec against pool sizes (length → number of candidate
    queries at that length).  Same spec + same pool sizes → identical
    trace, down to the last bit."""
    spec.validate()
    missing = set(spec.lengths.values) - set(pool_sizes)
    if missing:
        raise ValueError(
            f"no query pool for lengths {sorted(missing)}; "
            f"pools cover {sorted(pool_sizes)}")
    kwargs: Dict[str, Any] = {}
    if spec.process == "mmpp":
        kwargs = dict(burst_factor=spec.burst_factor, dwell_s=spec.dwell_s)
    elif spec.process == "diurnal":
        kwargs = dict(period_s=spec.period_s, depth=spec.depth)
    arrivals = make_arrivals(spec.process, spec.rate_qps, spec.n_requests,
                             seed=spec.seed, **kwargs)
    # attribute streams draw from independent child seeds so adding a
    # mixture value never perturbs the arrival times
    rng = np.random.default_rng(np.random.SeedSequence(spec.seed).spawn(1)[0])
    lengths = spec.lengths.sample(rng, spec.n_requests).astype(np.int64)
    topks = spec.topks.sample(rng, spec.n_requests).astype(np.int64)
    uniforms = rng.uniform(size=spec.n_requests)
    sizes = np.asarray([pool_sizes[int(ln)] for ln in lengths])
    pool_ids = np.minimum((uniforms * sizes).astype(np.int64), sizes - 1)
    return RequestTrace(spec=spec, arrivals_s=arrivals, lengths=lengths,
                        topks=topks, pool_ids=pool_ids)
