"""Closed-loop load harness over a live ServingEngine (DESIGN.md §12).

``run_trace`` replays a ``RequestTrace`` against a started engine in
open-loop fashion: each request is submitted at its *intended* arrival
time, and latency is measured from that intended time to completion —
not from the actual submit — so a stalled submitter cannot hide queueing
delay (the coordinated-omission trap).  Completion timestamps come from
future callbacks on the batcher thread's resolve, so no per-request
waiter thread is needed.

``sweep`` drives one trace shape at a ladder of offered loads, each
against a fresh engine (fresh metrics window), and derives
``max_sustainable_qps``: the highest offered load whose p99 meets the
SLO while the achieved throughput keeps up with the offered rate — past
the knee the queue grows without bound and both conditions fail
together.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Mapping, Optional, Sequence

import numpy as np

from repro.loadgen.workload import RequestTrace

# an offered load "keeps up" when achieved/offered stays above this —
# below it the run is queue-bound and its latencies are departure-rate
# artifacts, not service quality
SUSTAINED_FRAC = 0.9


def _percentile(xs: Sequence[float], p: float) -> float:
    if not len(xs):
        return 0.0
    xs = sorted(xs)
    rank = min(len(xs) - 1, max(0, int(round(p / 100.0 * (len(xs) - 1)))))
    return xs[rank]


@dataclasses.dataclass
class LoadResult:
    """One trace replay: latency distribution + engine telemetry +
    per-request answers (kept so policy A/B runs can assert
    bit-identity)."""

    offered_qps: float
    achieved_qps: float
    n_requests: int
    wall_s: float
    latency_p50_ms: float
    latency_p95_ms: float
    latency_p99_ms: float
    queue_depth_p50: float
    queue_depth_p95: float
    queue_depth_max: float
    batch_size_mean: float
    batch_wait_ms_mean: float
    batch_occupancy_mean: float
    batch_histogram: Dict[int, int]
    stage_us: Dict[str, float]
    ids: List[np.ndarray]
    dists: List[np.ndarray]

    def to_row(self) -> Dict[str, Any]:
        """Flat JSON-ready dict (per-request answers elided)."""
        row = {f.name: getattr(self, f.name)
               for f in dataclasses.fields(self)
               if f.name not in ("ids", "dists", "batch_histogram",
                                 "stage_us")}
        row["batch_histogram"] = {str(k): v for k, v
                                  in sorted(self.batch_histogram.items())}
        row["stage_us"] = dict(self.stage_us)
        return row

    def same_answers(self, other: "LoadResult") -> bool:
        """Bit-identical top-k ids and distances, request by request."""
        if len(self.ids) != len(other.ids):
            return False
        return all(
            np.array_equal(a, b) and np.array_equal(c, d)
            for a, b, c, d in zip(self.ids, other.ids,
                                  self.dists, other.dists))


def run_trace(engine, trace: RequestTrace,
              pools: Mapping[int, Any],
              timeout_s: float = 300.0) -> LoadResult:
    """Replay ``trace`` against a *started* engine; block until every
    request resolves.  ``pools`` maps query length → array of shape
    ``(pool_size, length)`` (any indexable returning a 1-D query)."""
    n = len(trace)
    missing = set(int(x) for x in np.unique(trace.lengths)) - \
        set(int(k) for k in pools)
    if missing:
        raise ValueError(f"trace needs query pools for lengths "
                         f"{sorted(missing)}; pools cover "
                         f"{sorted(int(k) for k in pools)}")
    done_at: List[Optional[float]] = [None] * n

    def stamp(k: int) -> Callable:
        def _cb(_fut) -> None:
            done_at[k] = time.perf_counter()
        return _cb

    futures = []
    t0 = time.perf_counter()
    for k in range(n):
        target = t0 + float(trace.arrivals_s[k])
        delay = target - time.perf_counter()
        if delay > 0:
            time.sleep(delay)
        fut = engine.submit(pools[int(trace.lengths[k])]
                            [int(trace.pool_ids[k])])
        fut.add_done_callback(stamp(k))
        futures.append((k, target, fut))

    lat_ms, ids, dists = [], [], []
    for k, target, fut in futures:
        res = fut.result(timeout=timeout_s)
        topk = int(trace.topks[k])
        # per-request topk ≤ engine topk serves as a prefix truncation
        # (top-k lists are sorted, so the prefix is the exact answer)
        ids.append(np.asarray(res.ids[:topk]))
        dists.append(np.asarray(res.dists[:topk]))
        lat_ms.append((done_at[k] - target) * 1e3)
    wall_s = max(filter(None, done_at)) - t0

    snap = engine.metrics.snapshot()
    stage_us = {k.replace("stage_", "").replace("_us_per_batch_mean", ""): v
                for k, v in snap.items() if k.startswith("stage_")}
    return LoadResult(
        offered_qps=trace.spec.rate_qps,
        achieved_qps=n / wall_s,
        n_requests=n,
        wall_s=wall_s,
        latency_p50_ms=_percentile(lat_ms, 50),
        latency_p95_ms=_percentile(lat_ms, 95),
        latency_p99_ms=_percentile(lat_ms, 99),
        queue_depth_p50=snap["queue_depth_p50"],
        queue_depth_p95=snap["queue_depth_p95"],
        queue_depth_max=snap["queue_depth_max"],
        batch_size_mean=snap["batch_size_mean"],
        batch_wait_ms_mean=snap["batch_wait_ms_mean"],
        batch_occupancy_mean=snap["batch_occupancy_mean"],
        batch_histogram=engine.metrics.batch_histogram(),
        stage_us=stage_us,
        ids=ids, dists=dists)


def sweep(engine_factory: Callable[[], Any], spec, offered_loads,
          pools: Mapping[int, Any], slo_p99_ms: float,
          timeout_s: float = 300.0):
    """Replay ``spec`` at each offered load, fresh engine per point.

    ``engine_factory`` returns an *unstarted* engine (fresh metrics each
    point, so one saturated run cannot pollute the next point's
    percentiles).  Returns ``(results, max_sustainable_qps)`` —
    the latter is 0.0 when even the lowest load misses the SLO.
    """
    from repro.loadgen.workload import generate_trace
    pool_sizes = {int(k): int(len(v)) for k, v in pools.items()}
    results: List[LoadResult] = []
    best = 0.0
    for load in offered_loads:
        trace = generate_trace(spec.replace(rate_qps=float(load)),
                               pool_sizes)
        engine = engine_factory()
        with engine:
            res = run_trace(engine, trace, pools, timeout_s=timeout_s)
        results.append(res)
        if res.latency_p99_ms <= slo_p99_ms and \
                res.achieved_qps >= SUSTAINED_FRAC * res.offered_qps:
            best = max(best, res.offered_qps)
    return results, best
