from repro.checkpoint.checkpointer import (Checkpointer, all_steps,
                                           latest_step, save_checkpoint,
                                           restore_checkpoint)

__all__ = ["Checkpointer", "all_steps", "latest_step", "save_checkpoint",
           "restore_checkpoint"]
