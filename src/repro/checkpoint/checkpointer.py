"""Fault-tolerant checkpointing (no external deps — npz shards + manifest).

Design (maps to multi-host practice):
  * one ``shard_<k>.npz`` per host (here: per logical shard), containing
    the host-local slices of every array, written to a temp dir and
    atomically renamed — a crashed writer never corrupts ``latest``;
  * a JSON manifest with step, tree structure, global shapes and the
    sharding layout used at save time;
  * **resharding restore**: arrays are reassembled to global shape and
    re-laid-out for the *current* mesh — restoring a 512-chip checkpoint
    onto 256 chips (elastic downscale) or vice versa just works;
  * retention: keep the last ``keep`` checkpoints (crash-safe GC order:
    new checkpoint is durable before old ones are removed);
  * optional async save (thread) so the train loop isn't blocked.

Restart protocol: trainers call ``latest_step(dir)`` on boot and resume
from there — combined with the seeded, offset-indexed data pipeline this
gives deterministic recovery from node failure.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from pathlib import Path
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

PyTree = Any


def _flatten_with_paths(tree: PyTree) -> List[Tuple[str, Any]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        key = "/".join(
            str(p.key) if hasattr(p, "key") else str(getattr(p, "idx", p))
            for p in path)
        out.append((key, leaf))
    return out


def save_checkpoint(directory: str | Path, step: int, tree: PyTree,
                    keep: int = 3, n_shards: int = 1) -> Path:
    """Write checkpoint ``step`` atomically; returns the final path."""
    directory = Path(directory)
    directory.mkdir(parents=True, exist_ok=True)
    tmp = directory / f".tmp_step_{step}_{os.getpid()}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir()

    leaves = _flatten_with_paths(tree)
    manifest: Dict[str, Any] = {"step": step, "time": time.time(),
                                "n_shards": n_shards, "arrays": {}}
    shards: List[Dict[str, np.ndarray]] = [{} for _ in range(n_shards)]
    for key, leaf in leaves:
        arr = np.asarray(leaf)
        manifest["arrays"][key] = {"shape": list(arr.shape),
                                   "dtype": str(arr.dtype),
                                   "shard_axis": 0 if arr.ndim and
                                   arr.shape[0] % n_shards == 0 and
                                   n_shards > 1 else None}
        ax = manifest["arrays"][key]["shard_axis"]
        if ax is None:
            shards[0][key] = arr
        else:
            for k, piece in enumerate(np.split(arr, n_shards, axis=ax)):
                shards[k][key] = piece
    for k, shard in enumerate(shards):
        np.savez(tmp / f"shard_{k}.npz", **shard)
    (tmp / "manifest.json").write_text(json.dumps(manifest))

    final = directory / f"step_{step:010d}"
    if final.exists():
        shutil.rmtree(final)
    os.replace(tmp, final)                       # atomic publish

    steps = sorted(all_steps(directory))
    for old in steps[:-keep]:
        shutil.rmtree(directory / f"step_{old:010d}", ignore_errors=True)
    return final


def all_steps(directory: str | Path) -> List[int]:
    directory = Path(directory)
    out = []
    if not directory.exists():
        return out
    for p in directory.iterdir():
        if p.name.startswith("step_") and (p / "manifest.json").exists():
            out.append(int(p.name.split("_")[1]))
    return sorted(out)


def latest_step(directory: str | Path) -> Optional[int]:
    steps = all_steps(directory)
    return steps[-1] if steps else None


def restore_checkpoint(directory: str | Path, tree_like: PyTree,
                       step: Optional[int] = None,
                       shardings: Optional[PyTree] = None) -> Tuple[int, PyTree]:
    """Restore into the structure of ``tree_like``; optionally re-shard
    (``shardings`` may target a *different* mesh than at save time)."""
    directory = Path(directory)
    step = step if step is not None else latest_step(directory)
    if step is None:
        raise FileNotFoundError(f"no checkpoints in {directory}")
    cdir = directory / f"step_{step:010d}"
    manifest = json.loads((cdir / "manifest.json").read_text())
    shards = [np.load(cdir / f"shard_{k}.npz")
              for k in range(manifest["n_shards"])]

    arrays: Dict[str, np.ndarray] = {}
    for key, info in manifest["arrays"].items():
        if info["shard_axis"] is None:
            arrays[key] = shards[0][key]
        else:
            arrays[key] = np.concatenate(
                [s[key] for s in shards], axis=info["shard_axis"])

    flat = _flatten_with_paths(tree_like)
    sh_flat = (_flatten_with_paths(shardings) if shardings is not None
               else [(k, None) for k, _ in flat])
    sh_map = dict(sh_flat)
    leaves = []
    for key, like in flat:
        if key not in arrays:
            raise KeyError(f"checkpoint missing array {key!r}")
        arr = arrays[key]
        want = tuple(like.shape)
        if tuple(arr.shape) != want:
            raise ValueError(f"{key}: checkpoint shape {arr.shape} != "
                             f"expected {want}")
        sh = sh_map.get(key)
        leaves.append(jax.device_put(arr, sh) if sh is not None
                      else jax.numpy.asarray(arr))
    structure = jax.tree_util.tree_structure(tree_like)
    return step, jax.tree_util.tree_unflatten(structure, leaves)


class Checkpointer:
    """Async-capable checkpoint manager with restart discovery."""

    def __init__(self, directory: str | Path, keep: int = 3,
                 async_save: bool = False, n_shards: int = 1):
        self.directory = Path(directory)
        self.keep = keep
        self.async_save = async_save
        self.n_shards = n_shards
        self._pending: Optional[threading.Thread] = None

    def save(self, step: int, tree: PyTree) -> None:
        self.wait()
        host_tree = jax.tree.map(np.asarray, tree)   # snapshot off-device
        if self.async_save:
            t = threading.Thread(
                target=save_checkpoint,
                args=(self.directory, step, host_tree, self.keep,
                      self.n_shards), daemon=True)
            t.start()
            self._pending = t
        else:
            save_checkpoint(self.directory, step, host_tree, self.keep,
                            self.n_shards)

    def wait(self) -> None:
        if self._pending is not None:
            self._pending.join()
            self._pending = None

    def restore_latest(self, tree_like: PyTree, shardings: PyTree = None
                       ) -> Tuple[Optional[int], PyTree]:
        if latest_step(self.directory) is None:
            return None, tree_like
        return restore_checkpoint(self.directory, tree_like,
                                  shardings=shardings)
